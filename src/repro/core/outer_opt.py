"""Outer optimizers (Algorithm 1, line 14).

The outer gradient Δ = θ^(t-1) − mean_i θ_i^(t) is treated as a gradient:
θ^(t) = OuterOpt(θ^(t-1), Δ). Paper findings (Fig 6):
  - Nesterov(lr=0.7, μ=0.9) is best — the default.
  - SGD(lr=1) reduces exactly to FedAvg (θ^(t) = mean θ_i).
  - Adam needs eps≈0.1 to be stable.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class OuterState(NamedTuple):
    buf: dict          # momentum buffer (or Adam m)
    buf2: dict         # Adam v (zeros otherwise)
    count: jnp.ndarray


def init(params) -> OuterState:
    z = lambda p: jnp.zeros_like(p)
    return OuterState(jax.tree.map(z, params), jax.tree.map(z, params),
                      jnp.zeros((), jnp.int32))


def update(delta, state: OuterState, params, *, kind: str, lr: float,
           momentum: float = 0.9, b2: float = 0.95, eps: float = 0.1,
           kernel_mode: str = "ref"):
    """Returns (new_params, new_state).

    ``kernel_mode`` != "ref" routes the Nesterov update (the paper's
    default outer optimizer) through the fused Pallas kernel — one VMEM
    pass over (θ, Δ, b) instead of two tree maps. Other outer-opt kinds
    always use the jnp tree maps (they are off the paper's main path).
    """
    count = state.count + 1
    # The outer step always runs at master precision: low-precision
    # deltas (e.g. from bf16 replicas under the pure-bf16 policy) are
    # upcast to the params' dtype first (identity for f32 deltas).
    delta = jax.tree.map(lambda d, p: d.astype(p.dtype), delta, params)

    if kind == "nesterov" and kernel_mode != "ref":
        from repro.kernels import ops as kops
        new_p, new_buf = kops.nesterov_update_tree(
            params, delta, state.buf, lr=lr, momentum=momentum,
            mode=kernel_mode)
        return new_p, OuterState(new_buf, state.buf2, count)

    if kind == "sgd":
        new_p = jax.tree.map(lambda p, d: p - lr * d, params, delta)
        return new_p, OuterState(state.buf, state.buf2, count)

    if kind == "sgdm":
        new_buf = jax.tree.map(lambda b, d: momentum * b + d,
                               state.buf, delta)
        new_p = jax.tree.map(lambda p, b: p - lr * b, params, new_buf)
        return new_p, OuterState(new_buf, state.buf2, count)

    if kind == "nesterov":
        new_buf = jax.tree.map(lambda b, d: momentum * b + d,
                               state.buf, delta)
        new_p = jax.tree.map(lambda p, b, d: p - lr * (momentum * b + d),
                             params, new_buf, delta)
        return new_p, OuterState(new_buf, state.buf2, count)

    if kind == "adam":
        b1 = momentum
        c1 = 1.0 - b1 ** count.astype(jnp.float32)
        c2 = 1.0 - b2 ** count.astype(jnp.float32)
        new_m = jax.tree.map(lambda m, d: b1 * m + (1 - b1) * d,
                             state.buf, delta)
        new_v = jax.tree.map(lambda v, d: b2 * v + (1 - b2) * d * d,
                             state.buf2, delta)
        new_p = jax.tree.map(
            lambda p, m, v: p - lr * (m / c1) / (jnp.sqrt(v / c2) + eps),
            params, new_m, new_v)
        return new_p, OuterState(new_m, new_v, count)

    raise ValueError(kind)
