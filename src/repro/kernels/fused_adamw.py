"""Fused AdamW update — Pallas TPU kernel.

The inner optimizer is DiLoCo's per-step memory bill: each AdamW step
reads (p, g, m, v) and writes (p, m, v) — 7 tensor-sized HBM transfers
that XLA sometimes splits across fusions. This kernel performs the whole
update in ONE VMEM pass per tile: a (block_r, 128)-tile of each operand
streams in, the update math runs on the VPU in f32, and the three
outputs stream out. Bandwidth-optimal: bytes moved = 4 reads + 3 writes,
nothing else.

Scalars (lr and the bias corrections c1 = 1-β1^t, c2 = 1-β2^t) arrive as
a small SMEM-resident array so the same compiled kernel serves every
step of the schedule.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import compat


def _adamw_kernel(sc_ref, p_ref, g_ref, m_ref, v_ref,
                  p_out, m_out, v_out, *, b1, b2, eps, weight_decay):
    lr, c1, c2 = sc_ref[0], sc_ref[1], sc_ref[2]
    p = p_ref[...].astype(jnp.float32)
    g = g_ref[...].astype(jnp.float32)
    m = m_ref[...].astype(jnp.float32)
    v = v_ref[...].astype(jnp.float32)
    m_new = b1 * m + (1.0 - b1) * g
    v_new = b2 * v + (1.0 - b2) * g * g
    step = (m_new / c1) / (jnp.sqrt(v_new / c2) + eps) + weight_decay * p
    p_out[...] = (p - lr * step).astype(p_out.dtype)
    m_out[...] = m_new.astype(m_out.dtype)
    v_out[...] = v_new.astype(v_out.dtype)


def fused_adamw(p, g, m, v, *, lr, c1, c2, b1=0.9, b2=0.95, eps=1e-8,
                weight_decay=0.1, block_rows: int = 256,
                interpret: bool = False):
    """One AdamW step on a single tensor of any shape.

    lr/c1/c2 may be traced scalars. Returns (p_new, m_new, v_new).
    """
    shape, dtype = p.shape, p.dtype
    n = p.size
    cols = 128
    rows = -(-n // cols)
    pad = rows * cols - n

    def to2d(x):
        x = x.reshape(-1)
        if pad:
            x = jnp.pad(x, (0, pad))
        return x.reshape(rows, cols)

    p2, g2, m2, v2 = map(to2d, (p, g, m, v))
    br = min(block_rows, rows)
    rows_p = -(-rows // br) * br
    if rows_p != rows:
        padr = rows_p - rows
        p2, g2, m2, v2 = (jnp.pad(x, ((0, padr), (0, 0)))
                          for x in (p2, g2, m2, v2))
    scalars = jnp.stack([jnp.asarray(lr, jnp.float32),
                         jnp.asarray(c1, jnp.float32),
                         jnp.asarray(c2, jnp.float32)])

    kernel = functools.partial(_adamw_kernel, b1=b1, b2=b2, eps=eps,
                               weight_decay=weight_decay)
    grid = (rows_p // br,)
    tile = pl.BlockSpec((br, cols), lambda i: (i, 0))
    outs = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[pl.BlockSpec(memory_space=compat.SMEM),
                  tile, tile, tile, tile],
        out_specs=(tile, tile, tile),
        out_shape=tuple(jax.ShapeDtypeStruct((rows_p, cols), d)
                        for d in (dtype, m.dtype, v.dtype)),
        compiler_params=compat.CompilerParams(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(scalars, p2, g2, m2, v2)

    def back(x, dt):
        return x.reshape(-1)[:n].reshape(shape).astype(dt)

    return (back(outs[0], dtype), back(outs[1], m.dtype),
            back(outs[2], v.dtype))
