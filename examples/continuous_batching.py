"""Continuous-batching serving demo (beyond-paper).

Eight requests with different prompt/generation lengths stream through
a 3-slot engine: finished slots refill immediately (vLLM-style), one
batched decode per tick, and every request's tokens are bit-identical
to running it alone (shared-clock RoPE alignment — see
launch/batching.py).

  PYTHONPATH=src python examples/continuous_batching.py
"""
import time

import jax
import numpy as np

from repro.launch.batching import ContinuousBatcher
from repro.models.registry import get_smoke_arch

arch = get_smoke_arch("qwen3_32b")
params, _ = arch.init(jax.random.PRNGKey(0), arch.cfg)
eng = ContinuousBatcher(arch, params, slots=3, cache_len=128)

rng = np.random.default_rng(0)
reqs = []
for i in range(8):
    L = int(rng.integers(4, 24))
    gen = int(rng.integers(4, 16))
    rid = eng.submit(rng.integers(0, arch.cfg.vocab_size, L), gen)
    reqs.append((rid, L, gen))
    print(f"submitted rid={rid} prompt={L} gen={gen}")

t0 = time.time()
ticks = 0
while eng.queue or any(r is not None for r in eng.active):
    eng.tick()
    ticks += 1
    if ticks % 5 == 0:
        print(f"tick {ticks:3d}: utilization {eng.utilization:.0%}, "
              f"{len(eng.finished)}/8 done")
out = eng.finished
dt = time.time() - t0
total = sum(len(v) for v in out.values())
print(f"\n{len(out)} requests, {total} tokens in {ticks} ticks "
      f"({dt:.1f}s incl. compiles)")
serial_ticks = sum(g for _, _, g in reqs)
print(f"serial decode would take {serial_ticks} ticks -> continuous "
      f"batching gave {serial_ticks / ticks:.1f}x tick-level speedup "
      f"on 3 slots")
for rid, L, gen in reqs:
    print(f"  rid={rid}: {out[rid][:8]}{'...' if gen > 8 else ''}")
