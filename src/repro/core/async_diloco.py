"""Asynchronous DiLoCo — the paper's stated future work (§5, third
limitation): "extend DiLoCo to the asynchronous setting, whereby
workers update the global parameter without ever waiting for any other
worker."

The barrier-free engine (``AsyncEngine``), event-driven:

* A ``faults.Scenario`` scripts the failure model — heterogeneous
  worker speeds, per-link WAN latency, outer-gradient drop with
  retry/backoff, preemption leave/join — and compiles it to a
  deterministic timeline of Arrival / Lost / Leave / Join events.
* A parameter server holds the global copy θ and the outer-optimizer
  state. Whenever ANY worker's outer gradient arrives, it is applied
  IMMEDIATELY — no barrier — at weight λ^τ / k: the 1/k is each
  worker's share of a round's evidence (synchronous DiLoCo averages k
  deltas; applying each at full weight over-steps k-fold), and λ^τ
  (τ = outer steps since dispatch) is the staleness discount for delay
  compensation (``cfg.staleness_lambda``).
* The delta Δ_i = θ^(dispatch) − θ_i is computed against the server's
  snapshot of the dispatch point; snapshots are version-keyed and
  pruned to live dispatch versions only.
* Under a quantized ``outer_grad_dtype`` (int4/bf16) each application
  ships as ONE flattened wire buffer through the PR 5 packed codec
  (``kernels.ops.wire_encode``/``wire_decode``) — the exact bytes a
  real pod→server transfer would carry — with a per-worker
  error-feedback residual (when ``cfg.error_feedback``) surviving
  across arbitrarily delayed applications. Float32 ships raw.
* A payload whose every send attempt drops is Lost: the worker keeps
  its own params under the SAME dispatch version (Fig 8 semantics), so
  its next successful delta spans both phases and recovers the mass.
* All state transitions live in TWO jitted functions whose carries are
  donated (``donate=True``): ``run_phase`` consumes (params, opt) in
  place and ``apply_arrival`` consumes (global, outer state, worker
  masters, residual) in place — dispatch snapshots are the only copies
  (they are real transfers in a deployment anyway).
* With all speeds equal and λ=1 an engine tick applies the same total
  update mass as one synchronous round (k deltas × 1/k), sequentially
  through the momentum buffer, and the f32 fault-free path is
  bit-identical to a reference sequential application (both tested).

State is checkpointable mid-run: ``state_to_tree`` flattens the full
bookkeeping (per-worker params + AdamW moments + residual + dispatch
version, live snapshots, outer state, event cursor) into a pure
nested-dict pytree for ``checkpoint.save``; a preempted-and-restored
run replays the identical event suffix (per-phase RNG is keyed by the
timeline's stable uid, not by host call order) and is bit-identical to
an uninterrupted one (tested).

``run_async`` keeps the seed's one-call simulation API on top.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.flatten_util import ravel_pytree

from repro.configs.base import DiLoCoConfig, TrainConfig
from repro.optim import adamw, precision
from . import diloco, faults, outer_opt


# ---------------------------------------------------------------------------
# state
# ---------------------------------------------------------------------------

@dataclass
class WorkerSlot:
    """One worker's server-side bookkeeping."""
    params: Any                 # working params (param_dtype)
    opt: adamw.AdamWState       # inner AdamW moments (+ master if mixed)
    residual: jnp.ndarray       # flat f32 error-feedback residual
    version: int                # outer version of the dispatch point
    active: bool                # False between Leave and Join


@dataclass
class AsyncState:
    """Everything a barrier-free run carries between events."""
    global_params: Any
    outer: outer_opt.OuterState
    workers: list
    snapshots: dict             # live dispatch version -> θ snapshot
    version: int = 0            # outer step count (applications so far)
    inner_done: int = 0         # global inner-step counter (lr schedule)
    events_done: int = 0        # timeline cursor (resume point)

    def live_versions(self) -> set:
        return ({w.version for w in self.workers if w.active}
                | {self.version})


def state_to_tree(state: AsyncState) -> dict:
    """Flatten an AsyncState into a pure nested-dict pytree of arrays
    (NamedTuples unpacked, int keys stringified, Python counters as 0-d
    arrays) — the layout ``checkpoint.save`` / ``restore_tree`` round-
    trips without needing a like-structured example."""
    workers = {}
    for i, w in enumerate(state.workers):
        d = {"params": w.params, "m": w.opt.m, "v": w.opt.v,
             "opt_count": w.opt.count, "residual": w.residual,
             "version": np.int64(w.version),
             "active": np.int64(w.active)}
        if w.opt.master is not None:
            d["master"] = w.opt.master
        workers[str(i)] = d
    return {
        "global": state.global_params,
        "outer": {"buf": state.outer.buf, "buf2": state.outer.buf2,
                  "count": state.outer.count},
        "workers": workers,
        "snapshots": {str(v): s for v, s in state.snapshots.items()},
        "counters": {"version": np.int64(state.version),
                     "inner_done": np.int64(state.inner_done),
                     "events_done": np.int64(state.events_done)},
    }


def state_from_tree(tree: dict, params_example) -> AsyncState:
    """Inverse of ``state_to_tree``. ``params_example`` supplies the
    real parameter-tree structure (restore_tree returns dict-ified
    trees; every params-shaped subtree is re-shaped onto it)."""
    from repro.checkpoint import checkpoint as ckpt
    like = lambda t: ckpt.reshape_like(t, params_example)
    workers = []
    for i in range(len(tree["workers"])):
        d = tree["workers"][str(i)]
        opt = adamw.AdamWState(
            m=like(d["m"]), v=like(d["v"]),
            count=jnp.asarray(d["opt_count"]),
            master=like(d["master"]) if "master" in d else None)
        workers.append(WorkerSlot(
            params=like(d["params"]), opt=opt,
            residual=jnp.asarray(d["residual"]),
            version=int(d["version"]), active=bool(int(d["active"]))))
    return AsyncState(
        global_params=like(tree["global"]),
        outer=outer_opt.OuterState(
            buf=like(tree["outer"]["buf"]),
            buf2=like(tree["outer"]["buf2"]),
            count=jnp.asarray(tree["outer"]["count"])),
        workers=workers,
        snapshots={int(v): like(s)
                   for v, s in tree["snapshots"].items()},
        version=int(tree["counters"]["version"]),
        inner_done=int(tree["counters"]["inner_done"]),
        events_done=int(tree["counters"]["events_done"]),
    )


# ---------------------------------------------------------------------------
# engine
# ---------------------------------------------------------------------------

class AsyncEngine:
    """Barrier-free DiLoCo driven by a ``faults.Scenario`` timeline.

    sample_fn(key, B, S) -> (B, S) int32 tokens — one worker's batch
    (pass a tuple of k callables for per-worker data shards).

    ``donate=False`` keeps every jitted carry un-donated (the
    donation-equivalence regression test runs both and compares
    bit-for-bit).
    """

    def __init__(self, loss_fn: Callable, sample_fn, cfg: DiLoCoConfig,
                 tcfg: TrainConfig, *, scenario: faults.Scenario | None
                 = None, total_steps: int | None = None,
                 eval_fn=None, eval_tokens=None, seed: int = 0,
                 donate: bool = True):
        if cfg.outer_grad_dtype not in ("float32", "bfloat16", "int4"):
            raise ValueError(
                f"unsupported outer_grad_dtype {cfg.outer_grad_dtype!r}")
        if getattr(cfg, "streaming_fragments", 0):
            raise ValueError(
                "transport='async' replaces the round schedule "
                "entirely; streaming_fragments must be 0")
        # validate λ eagerly (shared with the weight policy)
        faults.staleness_weight(0, cfg.staleness_lambda, cfg.k)
        self.cfg, self.tcfg = cfg, tcfg
        self.scenario = scenario or faults.Scenario.uniform(cfg.k)
        self.scenario.resolved_speeds(cfg.k)     # fail fast on shape
        self.eval_fn, self.eval_tokens = eval_fn, eval_tokens
        self.base_key = jax.random.PRNGKey(seed)
        self.donate = bool(donate)
        self._pol = precision.policy_of(cfg)
        self._mode = getattr(cfg, "kernel_mode", "ref")
        self._unravel = None                     # set on first init
        self._n_elems = None
        inner_step = diloco.make_inner_step(
            lambda p, b: loss_fn(p, b), tcfg,
            total_steps or tcfg.total_steps)
        self.loss_fn = loss_fn
        samplers = (tuple(sample_fn) if isinstance(sample_fn,
                                                   (tuple, list))
                    else (sample_fn,) * cfg.k)
        if len(samplers) != cfg.k:
            raise ValueError(
                f"need {cfg.k} per-worker samplers, got {len(samplers)}")
        self._run_phase = [self._make_run_phase(inner_step, fn)
                           for fn in samplers]
        self._apply = self._make_apply()

    # ---- jitted pieces ----

    def _make_run_phase(self, inner_step, sample_fn):
        cfg, tcfg = self.cfg, self.tcfg

        def run_phase(params, opt, key, step0):
            def body(carry, h):
                p, o = carry
                batch = {"tokens": sample_fn(
                    jax.random.fold_in(key, h), tcfg.batch_size,
                    tcfg.seq_len)}
                p, o, m = inner_step(p, o, batch, step0 + h)
                return (p, o), m["loss"]

            (params, opt), losses = jax.lax.scan(
                body, (params, opt), jnp.arange(cfg.H))
            return params, opt, losses.mean()

        if self.donate:
            return jax.jit(run_phase, donate_argnums=(0, 1))
        return jax.jit(run_phase)

    def _make_apply(self):
        cfg = self.cfg
        dt, mode = cfg.outer_grad_dtype, self._mode

        def apply_arrival(global_params, outer, msrc, residual,
                          snapshot, weight):
            # Δ = θ^(dispatch) − θ_i, master-vs-master, as ONE flat
            # wire payload (a single pod→server transfer)
            d, _ = ravel_pytree(jax.tree.map(
                lambda s, w: s - w.astype(s.dtype), snapshot, msrc))
            d_tot = d + residual
            if dt == "float32":
                local = d_tot                       # raw f32 wire
            else:
                from repro.kernels import ops as kops
                wire, _ = kops.wire_encode(d_tot, dt, mode=mode)
                local = kops.wire_decode(wire, d_tot.shape[0], dt,
                                         mode=mode)
            new_res = (d_tot - local if cfg.error_feedback
                       else jnp.zeros_like(residual))
            applied = self._unravel(local * weight)
            new_global, new_outer = outer_opt.update(
                applied, outer, global_params, kind=cfg.outer_opt,
                lr=cfg.outer_lr, momentum=cfg.outer_momentum,
                b2=cfg.outer_adam_b2, eps=cfg.outer_adam_eps,
                kernel_mode=mode)
            dnorm = jnp.sqrt(jnp.sum(jnp.square(local)))
            return new_global, new_outer, new_res, dnorm

        if self.donate:
            # snapshot (4) and weight (5) are NOT donated: a snapshot
            # can be the dispatch point of several in-flight payloads.
            # msrc (2) is not donated either — its buffers match no
            # output (global/outer already reuse the donated carry)
            # and the worker slot still reads it at re-dispatch.
            return jax.jit(apply_arrival, donate_argnums=(0, 1, 3))
        return jax.jit(apply_arrival)

    # ---- state construction ----

    def _dispatch(self, global_params, opt=None):
        """A fresh worker dispatch from θ: copied working params (the
        pod→worker transfer — never an alias, every carry is donated)
        and either brand-new AdamW moments or the survivor's moments
        with the master re-pointed at the new dispatch."""
        disp = precision.cast_tree(global_params, self._pol.param_dtype,
                                   fresh=True)
        if opt is None:
            opt = adamw.init(global_params, policy=self._pol)
        elif opt.master is not None:
            opt = opt._replace(master=jax.tree.map(jnp.copy,
                                                   global_params))
        return disp, opt

    def init_state(self, params0) -> AsyncState:
        flat, unravel = ravel_pytree(params0)
        self._unravel = unravel
        self._n_elems = int(flat.shape[0])
        workers = []
        for _ in range(self.cfg.k):
            p, o = self._dispatch(params0)
            # one residual buffer PER worker: the apply donates it, and
            # a shared zeros array would be deleted for everyone at the
            # first arrival
            workers.append(WorkerSlot(
                params=p, opt=o,
                residual=jnp.zeros((self._n_elems,), jnp.float32),
                version=0, active=True))
        return AsyncState(
            global_params=jax.tree.map(jnp.copy, params0),
            outer=outer_opt.init(params0),
            workers=workers,
            snapshots={0: jax.tree.map(jnp.copy, params0)})

    def _bind(self, state: AsyncState):
        """Re-attach the unravel closure after a checkpoint restore."""
        if self._unravel is None:
            flat, unravel = ravel_pytree(state.global_params)
            self._unravel = unravel
            self._n_elems = int(flat.shape[0])

    def wire_bytes(self) -> int:
        """Bytes ONE application ships worker→server (packed wire for
        quantized dtypes, raw f32 otherwise)."""
        from repro.kernels import ops as kops
        return kops.transport_bytes(
            self._n_elems, self.cfg.outer_grad_dtype,
            packed=self.cfg.outer_grad_dtype != "float32")

    # ---- event loop ----

    def _prune(self, state: AsyncState):
        """Drop snapshots no live dispatch can still reference. A live
        version must never be dropped (invariant, tested)."""
        live = state.live_versions()
        missing = live - set(state.snapshots)
        assert not missing, f"live dispatch versions {missing} pruned"
        state.snapshots = {v: s for v, s in state.snapshots.items()
                           if v in live}

    def run(self, state: AsyncState, *, ticks: int,
            max_events: int | None = None, recorder=None,
            on_crash=None):
        """Process the scenario timeline for ``ticks`` wall-clock ticks
        from ``state.events_done`` (so a restored state resumes exactly
        where it left off), optionally stopping after ``max_events``
        more events (mid-run checkpoint cut point). Returns
        (state, history) — one record per event, ``"event"`` keyed.

        ``recorder`` (an ``obs.metrics.RunRecorder``) receives each
        event record as it happens via ``async_event`` — purely
        host-side enrichment/printing; the computation is identical
        with or without it.

        ``on_crash(state)`` is invoked when a ``faults.Crash`` event is
        reached (the crash-grade injection path: the launcher SIGKILLs
        its own process there). The crash consumes no rng/uid, so a
        resume under the crash-free scenario replays the surviving
        events bit-identically. If ``on_crash`` returns, the engine
        simply continues (test mode).
        """
        cfg = self.cfg
        self._bind(state)
        events = self.scenario.timeline(cfg.k, ticks)
        todo = events[state.events_done:]
        if max_events is not None:
            todo = todo[:max_events]
        history = []

        def emit(rec):
            history.append(rec)
            if recorder is not None:
                recorder.async_event(rec)

        for ev in todo:
            if isinstance(ev, faults.Arrival):
                emit(self._on_arrival(state, ev))
            elif isinstance(ev, faults.Lost):
                emit(self._on_lost(state, ev))
            elif isinstance(ev, faults.Leave):
                w = state.workers[ev.worker]
                w.active = False
                self._prune(state)
                emit({"event": "leave", "tick": ev.tick,
                      "worker": ev.worker})
            elif isinstance(ev, faults.Crash):
                emit({"event": "crash", "tick": ev.tick})
                state.events_done += 1
                if on_crash is not None:
                    on_crash(state)
                continue
            elif isinstance(ev, faults.Join):
                w = state.workers[ev.worker]
                # moments died with the preemption: fresh opt, fresh
                # residual, dispatch from the current global copy
                w.params, w.opt = self._dispatch(state.global_params)
                w.residual = jnp.zeros((self._n_elems,), jnp.float32)
                w.version = state.version
                w.active = True
                emit({"event": "join", "tick": ev.tick,
                      "worker": ev.worker,
                      "version": state.version})
            state.events_done += 1
        return state, history

    def _phase(self, state: AsyncState, ev):
        """Run the H inner steps of the phase ``ev`` reports. RNG is
        keyed by the timeline's stable uid — independent of host call
        order, so a restored run resumes bit-identically."""
        w = state.workers[ev.worker]
        assert w.active, (
            f"arrival for departed worker {ev.worker}: the timeline "
            "guarantees delivered payloads outlive their sender")
        key = jax.random.fold_in(self.base_key, ev.uid)
        new_p, new_opt, mloss = self._run_phase[ev.worker](
            w.params, w.opt, key, jnp.asarray(state.inner_done))
        state.inner_done += self.cfg.H
        return w, new_p, new_opt, mloss

    def _on_arrival(self, state: AsyncState, ev):
        cfg = self.cfg
        w, new_p, new_opt, mloss = self._phase(state, ev)
        staleness = state.version - w.version
        weight = faults.staleness_weight(staleness,
                                         cfg.staleness_lambda, cfg.k)
        msrc = adamw.master_params(new_p, new_opt)
        state.global_params, state.outer, w.residual, dnorm = \
            self._apply(state.global_params, state.outer, msrc,
                        w.residual, state.snapshots[w.version],
                        jnp.asarray(weight, jnp.float32))
        state.version += 1
        # snapshot the new θ, then re-dispatch the worker from it.
        # Both are fresh copies: the next application donates the
        # global and run_phase donates the worker carry — an aliased
        # snapshot would be deleted out from under later arrivals.
        state.snapshots[state.version] = jax.tree.map(
            jnp.copy, state.global_params)
        w.params, w.opt = self._dispatch(state.global_params, new_opt)
        w.version = state.version
        self._prune(state)
        rec = {"event": "arrival", "tick": ev.tick, "worker": ev.worker,
               "uid": ev.uid, "attempt": ev.attempt,
               "staleness": staleness, "weight": float(weight),
               "version": state.version, "inner_loss": float(mloss),
               "delta_norm": float(dnorm),
               "wire_bytes": self.wire_bytes()}
        if self.eval_fn is not None and self.eval_tokens is not None:
            rec["val_loss"] = float(self.eval_fn(state.global_params,
                                                 self.eval_tokens))
            rec["ppl"] = float(np.exp(rec["val_loss"]))
        return rec

    def _on_lost(self, state: AsyncState, ev):
        """Every send attempt dropped: the phase ran but its delta
        never reached the server. Fig 8 semantics — the worker keeps
        its own params under the SAME dispatch version, so the next
        arrival's delta spans both phases (no silent mass loss); the
        error-feedback residual is untouched (nothing was quantized
        onto the wire)."""
        w, new_p, new_opt, mloss = self._phase(state, ev)
        w.params, w.opt = new_p, new_opt
        return {"event": "lost", "tick": ev.tick, "worker": ev.worker,
                "uid": ev.uid, "version_at_dispatch": w.version,
                "inner_loss": float(mloss)}


# ---------------------------------------------------------------------------
# seed-compatible one-call simulation API
# ---------------------------------------------------------------------------

@dataclass
class AsyncConfig:
    k: int = 8
    H: int = 10
    outer_lr: float = 0.7
    outer_momentum: float = 0.9
    staleness_lambda: float = 0.7   # discount per outer step of delay
    speeds: tuple = ()              # ticks per phase, len k (default 1s)


def run_async(loss_fn: Callable, sample_fn: Callable, params0,
              acfg: AsyncConfig, tcfg: TrainConfig, *, ticks: int,
              eval_fn=None, eval_tokens=None, seed: int = 0,
              scenario: faults.Scenario | None = None,
              dcfg: DiLoCoConfig | None = None, donate: bool = True):
    """Simulate ``ticks`` wall-clock units of barrier-free DiLoCo; one
    tick = the fastest worker's phase time. Returns (global_params,
    history) where history holds one dict per Arrival (plus marked
    lost/leave/join records under a faulty ``scenario``).

    ``dcfg`` overrides the DiLoCoConfig derived from ``acfg`` (for
    quantized wire / error feedback / alternate outer opts)."""
    if dcfg is None:
        dcfg = DiLoCoConfig(
            k=acfg.k, H=acfg.H, outer_lr=acfg.outer_lr,
            outer_momentum=acfg.outer_momentum, transport="async",
            staleness_lambda=acfg.staleness_lambda)
    if scenario is None:
        scenario = faults.Scenario(speeds=tuple(acfg.speeds)
                                   or (1,) * acfg.k)
    eng = AsyncEngine(loss_fn, sample_fn, dcfg, tcfg,
                      scenario=scenario, eval_fn=eval_fn,
                      eval_tokens=eval_tokens, seed=seed, donate=donate)
    state = eng.init_state(params0)
    state, history = eng.run(state, ticks=ticks)
    arrivals = [r for r in history if r["event"] == "arrival"]
    return state.global_params, (arrivals if scenario.drop_prob == 0
                                 and not scenario.preemptions
                                 else history)
