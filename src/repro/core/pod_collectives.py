"""Real pod-axis collectives for the streaming outer sync.

``core/streaming.py``'s simulated transport averages replica-stacked
arrays on one device — nothing crosses a mesh boundary. This module is
the deployable counterpart: each DiLoCo replica lives on its own slice
of the mesh's ``"pod"`` axis (``launch/mesh.py``'s multi-pod layout),
the streaming round runs under ``shard_map``, inner steps are pure
pod-local compute (manual sharding makes "zero cross-pod collectives
during inner training" *definitional*, not emergent), and each
fragment's outer gradient is reduced by a genuine cross-pod collective
at its staggered offset inside the scanned round.

Per transport precision the fragment reduction is:

  float32   weighted psum — ``lax.psum`` of each pod's partial
            ``tensordot(m_local, Δ_local)`` over the pod axis, i.e. a
            real all-reduce of fragment-size bytes.  With 0/1
            drop/active masks and uniform weights this is *bit-identical*
            to the simulated ``tensordot(m, Δ)`` (masked products are
            exact, and XLA's sequential all-reduce matches the dot's
            FMA accumulation order — tested); fractional per-shard
            weights round differently under FMA and agree to ~1 ulp.
  bfloat16  the per-replica quantized payload is exactly representable
            in bf16, so the wire carries real bf16: ``all_gather`` the
            bf16 fragment over the pod axis, upcast (exact), and reduce
            locally with the simulated path's op sequence.
  int4      per-replica payloads (scale blocks are formed on each pod's
            local shard, so they can never mix two pods' values) are
            all-gathered and reduced locally. With ``pack_wire`` (the
            default) the gather ships the REAL packed pair — nibble-
            packed int8 codes + per-block f32 scales laid out in ONE
            byte buffer per fragment (``ops.wire_encode``), all leaf
            regions coalesced, so the lowered HLO carries exactly the
            bytes ``ops.transport_bytes(..., packed=True)`` charges and
            issues one pod-axis all-gather per fragment per sync.
            ``pack_wire=False`` keeps the legacy fake-quant transport:
            the gather ships dequantized f32 (≈7.5× the packed bytes)
            and the wire is charged by the static model only.

Quantized transports agree with the simulated path within quant-error
bounds rather than bitwise: the payload *values* are identical, but XLA
re-fuses the quantize arithmetic into different surrounding ops per
program, so an element sitting exactly on a rounding tie may take the
adjacent code (one transport quantization step) — tested.

Quantized collectives gather rather than psum because summing encoded
payloads is meaningless (per-block scales differ per pod) — gather +
local dequant-reduce is how production quantized all-reduces work, and
the local reduction doubles as a run-to-run-deterministic reduction
order, independent of topology.

Error-feedback residuals (``StreamState.residual``) and AdamW moments
are pod-local state: they are sharded over the pod axis and never
touch the wire.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import NamedSharding, PartitionSpec as P

POD_AXIS = "pod"


def pods_of(mesh) -> int:
    """Size of the mesh's pod axis (1 when absent)."""
    return dict(zip(mesh.axis_names, mesh.devices.shape)).get(POD_AXIS, 1)


def validate_mesh(mesh, k: int) -> int:
    """Check ``mesh`` can host ``k`` replicas on its pod axis; returns
    the pod count. Replicas are laid out in contiguous bands of
    ``k // pods`` per pod, so pods must divide k."""
    if mesh is None:
        raise ValueError(
            "transport='sharded' needs a mesh with a 'pod' axis: pass "
            "mesh=... to make_round/make_run (see launch/mesh.py)")
    if POD_AXIS not in mesh.axis_names:
        raise ValueError(
            f"transport='sharded' needs a '{POD_AXIS}' mesh axis, got "
            f"axes {mesh.axis_names}")
    pods = pods_of(mesh)
    if k % pods != 0:
        raise ValueError(
            f"k={k} replicas cannot be banded over {pods} pods: pods "
            "must divide k (one contiguous replica band per pod)")
    return pods


def local_band(k_local: int, axis: str = POD_AXIS):
    """Start index of this pod's replica band (traced; shard_map only)."""
    return jax.lax.axis_index(axis) * k_local


def band_slice(x, k_local: int, axis_name: str = POD_AXIS):
    """This pod's (k_local, ...) band of a replicated (k, ...) array."""
    return jax.lax.dynamic_slice_in_dim(
        x, local_band(k_local, axis_name), k_local, 0)


def fragment_mean(d_local, m_full, m_local, denom, *, dtype: str,
                  axis: str = POD_AXIS):
    """Reduce one fragment leaf's outer gradient across pods.

    d_local: (k_local, ...) per-replica deltas, already transport-
    quantized (``quant_roundtrip`` values). m_full/m_local: the (k,)
    communication mask and this pod's band of it. denom: the (exact,
    replicated) mask sum. Returns the masked mean, replicated.
    """
    if dtype == "float32":
        part = jnp.tensordot(m_local, d_local, axes=(0, 0))
        return jax.lax.psum(part, axis) / denom
    gathered = fragment_gather(d_local, dtype=dtype, axis=axis)
    # the exact op the simulated transport runs on its stacked array —
    # bit-identical reduction, deterministic order on any topology
    return jnp.tensordot(m_full, gathered, axes=(0, 0)) / denom


def fragment_gather(d_local, *, dtype: str, axis: str = POD_AXIS):
    """The collective half of the quantized ``fragment_mean``: gather
    one fragment leaf's per-replica payload over the pod axis WITHOUT
    reducing it. The deferred streaming round (quantized, τ>0) issues
    this at the send offset and runs the mask-reduce τ inner steps
    later at the apply, so the gather's result has no consumer until
    the overlap window has elapsed. Returns (k, ...) in replica order,
    replicated."""
    if dtype == "bfloat16":
        # the quantized payload is on the bf16 grid: ship real bf16
        # bytes and upcast losslessly on arrival
        wire = jax.lax.all_gather(d_local.astype(jnp.bfloat16), axis,
                                  axis=0, tiled=True)
        return wire.astype(d_local.dtype)
    # int4 fake-quant payload; codes+scales packing is modeled by
    # the static wire accounting (ops.transport_bytes)
    return jax.lax.all_gather(d_local, axis, axis=0, tiled=True)


def gather_wire(wire_local, *, axis: str = POD_AXIS):
    """THE packed-wire collective: all-gather one fragment's coalesced
    per-replica wire buffers over the pod axis. wire_local:
    (k_local, W) — every leaf region's packed payload concatenated —
    returns (k, W) with every pod's band in replica order. One call per
    fragment per sync is the whole cross-pod bill of the quantized
    sharded transport."""
    return jax.lax.all_gather(wire_local, axis, axis=0, tiled=True)


def replica_mean(x_local, *, axis: str = POD_AXIS):
    """Global mean of a metric carried per local replica band."""
    return jax.lax.pmean(x_local.mean(), axis)


# ---------------------------------------------------------------------------
# state sharding specs / placement
# ---------------------------------------------------------------------------

def stream_state_specs(state, axis: str = POD_AXIS):
    """PartitionSpec pytree matching a ``streaming.StreamState``:
    per-replica leaves (working params, AdamW m/v/count/master,
    error-feedback residual) band-sharded over the pod axis on their
    leading (k,) dim; global params, outer state, pending fragments,
    the armed latch and the in-flight collective buffers replicated
    (every pod computes them identically from the replicated collective
    results — an all-gather's output is the same on every pod)."""
    shard = lambda t: jax.tree.map(lambda _: P(axis), t)
    rep = lambda t: jax.tree.map(lambda _: P(), t)
    base = state.base._replace(
        global_params=rep(state.base.global_params),
        outer_state=rep(state.base.outer_state),
        replica_params=shard(state.base.replica_params),
        inner_state=shard(state.base.inner_state),
        outer_t=P(),
        inner_steps_done=P())
    return state._replace(
        base=base,
        pending=rep(state.pending),
        armed=P(),
        residual=(None if state.residual is None
                  else shard(state.residual)),
        inflight=(None if getattr(state, "inflight", None) is None
                  else rep(state.inflight)))


def shard_stream_state(state, mesh, axis: str = POD_AXIS):
    """Place a StreamState on ``mesh``: replica state banded over the
    pod axis, shared state replicated. Use before the first sharded
    ``make_run`` call so the donated carry starts resident.

    Every returned leaf is a FRESH buffer: ``jax.device_put`` is the
    identity when a leaf already carries the target sharding, and
    handing an aliased leaf to the donated run would delete the
    caller's array with it (the donated-carry footgun) — so identity
    placements are copied explicitly."""
    validate_mesh(mesh, jax.tree.leaves(state.base.replica_params)[0]
                  .shape[0])
    specs = stream_state_specs(state, axis)

    def place(x, s):
        y = jax.device_put(x, NamedSharding(mesh, s))
        return y.copy() if y is x else y

    return jax.tree.map(place, state, specs)


def shard_round_body(core, mesh, state_specs):
    """Wrap an un-jitted streaming round core in shard_map over the pod
    axis: state per ``state_specs``; key, masks and weights replicated;
    outputs (state, metrics) with metrics replicated (they are pmean'd
    inside). check_rep=False: replication of the shared state is
    guaranteed by construction (all pods consume identical collective
    results), which the static checker cannot see."""
    return shard_map(core, mesh=mesh,
                     in_specs=(state_specs, P(), P(), P(), P()),
                     out_specs=(state_specs, P()),
                     check_rep=False)
