"""starcoder2-7b [dense, arXiv:2402.19173]: 32L, d_model=4608, 36 heads,
GQA kv=4, d_ff=18432, vocab=49152, RoPE, biased non-gated GELU MLP."""
from .base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="starcoder2-7b", family="dense",
        n_layers=32, d_model=4608, n_heads=36, n_kv_heads=4,
        d_ff=18_432, vocab_size=49_152,
        pos_emb="rope", rope_theta=1e5, norm="layernorm",
        act="gelu", mlp_gated=False, attn_bias=True, mlp_bias=True,
        tie_embeddings=True,
    )


def smoke_config() -> ModelConfig:
    return config().replace(
        name="starcoder2-smoke", n_layers=2, d_model=128, n_heads=4,
        n_kv_heads=2, d_ff=512, vocab_size=256, attn_chunk=64)
