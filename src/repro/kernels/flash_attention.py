"""Blocked online-softmax (flash) attention — Pallas TPU kernel.

DiLoCo's inner-loop compute at long context is dominated by attention;
this kernel is the TPU-native formulation: the (Sq, Skv) score matrix is
never materialized in HBM — q/k/v tiles stream HBM→VMEM per BlockSpec,
the MXU consumes (block_q × d)·(d × block_k) tiles, and the running
max/denominator live in VMEM scratch across the sequential kv grid axis.

Layout: q (B, H, Sq, d); k/v (B, G, Skv, d), GQA via H % G == 0 (the
kv-head index_map folds h -> h // rep so kv tiles are re-read, not
replicated, across the query heads of a group).

Grid: (B, H, n_qblocks, n_kvblocks) — first three parallel, the kv axis
"arbitrary" (sequential) so scratch accumulators carry across it.
Causal/sliding-window masking is applied per-tile from absolute
positions; fully-masked tiles short-circuit via ``pl.when``.

Supports self-attention (Sq == Skv, causal, optional window) — the
training/prefill hot path. Decode (Sq == 1) uses the jnp ref (a matvec —
memory-bound, no MXU win from a custom kernel).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from . import compat

NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                 scale: float, causal: bool, window: int, block_q: int,
                 block_k: int, n_kv: int, kv_len: int, q_offset: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    # absolute positions of this tile's queries and keys
    q_pos = q_offset + iq * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 0)
    k_pos = ik * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 1)

    # tile-level skip: causal => skip tiles strictly above the diagonal;
    # window => skip tiles entirely left of the window
    q_first = q_offset + iq * block_q
    q_last = q_first + block_q - 1
    k_first = ik * block_k
    k_last = k_first + block_k - 1
    live = True
    if causal:
        live = k_first <= q_last
    if window and window > 0:
        live = jnp.logical_and(live, k_last > q_first - window)

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale          # (bq, d)
        k = k_ref[0, 0].astype(jnp.float32)                  # (bk, d)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)              # (bq, bk)
        ok = k_pos < kv_len
        if causal:
            ok = jnp.logical_and(ok, k_pos <= q_pos)
        if window and window > 0:
            ok = jnp.logical_and(ok, k_pos > q_pos - window)
        s = jnp.where(ok, s, NEG_INF)

        m_prev = m_ref[:, :1]                                 # (bq, 1)
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)                                # (bq, bk)
        corr = jnp.exp(m_prev - m_new)                        # (bq, 1)
        l_ref[:, :1] = l_ref[:, :1] * corr + jnp.sum(p, 1, keepdims=True)
        m_ref[:, :1] = m_new
        v = v_ref[0, 0].astype(jnp.float32)                   # (bk, d)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(ik == n_kv - 1)
    def _finish():
        l = jnp.maximum(l_ref[:, :1], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


def _attn_kernel_fwd(q_ref, k_ref, v_ref, o_ref, lse_ref, acc_ref,
                     m_ref, l_ref, *, scale, causal, window, block_q,
                     block_k, n_kv, kv_len, q_offset):
    """Forward that additionally writes the per-row logsumexp L = m +
    log(l) — the single residual the backward kernels need to
    recompute the probabilities on-chip."""
    _attn_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref,
                 scale=scale, causal=causal, window=window,
                 block_q=block_q, block_k=block_k, n_kv=n_kv,
                 kv_len=kv_len, q_offset=q_offset)

    @pl.when(pl.program_id(3) == n_kv - 1)
    def _store_lse():
        l = jnp.maximum(l_ref[:, :1], 1e-30)
        lse_ref[0, 0] = (m_ref[:, :1] + jnp.log(l))[:, 0]


def _bwd_dq_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
                   dq_ref, acc_ref, *, scale, causal, window, block_q,
                   block_k, n_kv, kv_len, q_offset):
    """dq: grid (B, H, n_q, n_kv); kv sequential; p recomputed per tile
    from (q, k, L) — the (Sq, Skv) matrix never exists in HBM."""
    iq, ik = pl.program_id(2), pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_pos = q_offset + iq * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 0)
    k_pos = ik * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 1)
    live = True
    if causal:
        live = ik * block_k <= q_offset + iq * block_q + block_q - 1
    if window and window > 0:
        live = jnp.logical_and(
            live, ik * block_k + block_k - 1
            > q_offset + iq * block_q - window)

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale
        k = k_ref[0, 0].astype(jnp.float32)
        v = v_ref[0, 0].astype(jnp.float32)
        do = do_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        ok = k_pos < kv_len
        if causal:
            ok = jnp.logical_and(ok, k_pos <= q_pos)
        if window and window > 0:
            ok = jnp.logical_and(ok, k_pos > q_pos - window)
        p = jnp.where(ok, jnp.exp(s - lse_ref[0, 0][:, None]), 0.0)
        dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        ds = p * (dp - delta_ref[0, 0][:, None])
        acc_ref[...] += jax.lax.dot_general(
            ds, k, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32) * scale

    @pl.when(ik == n_kv - 1)
    def _finish():
        dq_ref[0, 0] = acc_ref[...].astype(dq_ref.dtype)


def _bwd_dkv_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
                    dk_ref, dv_ref, dk_acc, dv_acc, *, scale, causal,
                    window, block_q, block_k, n_q, kv_len, q_offset):
    """dk/dv: grid (B, H, n_kv, n_q); q sequential; accumulates the
    per-query-head contributions (summed over the GQA group outside)."""
    ik, iq = pl.program_id(2), pl.program_id(3)

    @pl.when(iq == 0)
    def _init():
        dk_acc[...] = jnp.zeros_like(dk_acc)
        dv_acc[...] = jnp.zeros_like(dv_acc)

    q_pos = q_offset + iq * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 0)
    k_pos = ik * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 1)
    live = True
    if causal:
        live = ik * block_k <= q_offset + iq * block_q + block_q - 1
    if window and window > 0:
        live = jnp.logical_and(
            live, ik * block_k + block_k - 1
            > q_offset + iq * block_q - window)

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale
        k = k_ref[0, 0].astype(jnp.float32)
        v = v_ref[0, 0].astype(jnp.float32)
        do = do_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        ok = k_pos < kv_len
        if causal:
            ok = jnp.logical_and(ok, k_pos <= q_pos)
        if window and window > 0:
            ok = jnp.logical_and(ok, k_pos > q_pos - window)
        p = jnp.where(ok, jnp.exp(s - lse_ref[0, 0][:, None]), 0.0)
        dv_acc[...] += jax.lax.dot_general(
            p, do, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        ds = p * (dp - delta_ref[0, 0][:, None])
        dk_acc[...] += jax.lax.dot_general(
            ds, q, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(iq == n_q - 1)
    def _finish():
        dk_ref[0, 0] = dk_acc[...].astype(dk_ref.dtype)
        dv_ref[0, 0] = dv_acc[...].astype(dv_ref.dtype)


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    scale: float | None = None, block_q: int = 128,
                    block_k: int = 128, q_offset: int = 0,
                    interpret: bool = False):
    """q: (B, H, Sq, d); k/v: (B, G, Skv, d). Returns (B, H, Sq, d).

    Sq/Skv are padded to block multiples internally; ``q_offset`` is the
    absolute position of q[0] (prefill continuation). d should be a
    multiple of 128 for MXU alignment on real TPUs (not enforced —
    interpret mode accepts anything).
    """
    B, H, Sq, d = q.shape
    _, G, Sk, _ = k.shape
    assert H % G == 0, (H, G)
    rep = H // G
    scale = d ** -0.5 if scale is None else scale

    bq = min(block_q, max(Sq, 8))
    bk = min(block_k, max(Sk, 8))
    Sq_p = -(-Sq // bq) * bq
    Sk_p = -(-Sk // bk) * bk
    if Sq_p != Sq:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, Sq_p - Sq), (0, 0)))
    if Sk_p != Sk:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, Sk_p - Sk), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, Sk_p - Sk), (0, 0)))
    n_q, n_kv = Sq_p // bq, Sk_p // bk

    kernel = functools.partial(
        _attn_kernel, scale=scale, causal=causal, window=window,
        block_q=bq, block_k=bk, n_kv=n_kv, kv_len=Sk,
        q_offset=q_offset + (Sk - Sq if causal and Sq != Sk else 0))

    out = pl.pallas_call(
        kernel,
        grid=(B, H, n_q, n_kv),
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda b, h, i, j, rep=rep: (b, h // rep, j, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda b, h, i, j, rep=rep: (b, h // rep, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, d),
                               lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq_p, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, d), jnp.float32),
            pltpu.VMEM((bq, 128), jnp.float32),
            pltpu.VMEM((bq, 128), jnp.float32),
        ],
        compiler_params=compat.CompilerParams(
            dimension_semantics=(
                "parallel", "parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v)
    return out[:, :, :Sq]


# ---------------------------------------------------------------------------
# differentiable flash attention (fwd saves only (o, L); backward
# kernels recompute the probabilities on-chip — the (Sq, Skv) matrix
# never reaches HBM in either pass)
# ---------------------------------------------------------------------------

def _pad_to(x, dim, mult):
    size = x.shape[dim]
    pad = -size % mult
    if pad == 0:
        return x
    cfgp = [(0, 0)] * x.ndim
    cfgp[dim] = (0, pad)
    return jnp.pad(x, cfgp)


def _fwd_lse(q, k, v, *, causal, window, scale, bq, bk, q_offset,
             interpret):
    B, H, Sq, d = q.shape
    _, G, Sk, _ = k.shape
    rep = H // G
    q = _pad_to(q, 2, bq)
    k = _pad_to(k, 2, bk)
    v = _pad_to(v, 2, bk)
    Sq_p, Sk_p = q.shape[2], k.shape[2]
    n_q, n_kv = Sq_p // bq, Sk_p // bk
    kernel = functools.partial(
        _attn_kernel_fwd, scale=scale, causal=causal, window=window,
        block_q=bq, block_k=bk, n_kv=n_kv, kv_len=Sk,
        q_offset=q_offset + (Sk - Sq if causal and Sq != Sk else 0))
    o, lse = pl.pallas_call(
        kernel,
        grid=(B, H, n_q, n_kv),
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda b, h, i, j, rep=rep: (b, h // rep, j, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda b, h, i, j, rep=rep: (b, h // rep, j, 0)),
        ],
        out_specs=(
            pl.BlockSpec((1, 1, bq, d), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bq), lambda b, h, i, j: (b, h, i)),
        ),
        out_shape=(jax.ShapeDtypeStruct((B, H, Sq_p, d), q.dtype),
                   jax.ShapeDtypeStruct((B, H, Sq_p), jnp.float32)),
        scratch_shapes=[
            pltpu.VMEM((bq, d), jnp.float32),
            pltpu.VMEM((bq, 128), jnp.float32),
            pltpu.VMEM((bq, 128), jnp.float32),
        ],
        compiler_params=compat.CompilerParams(
            dimension_semantics=(
                "parallel", "parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v)
    return o[:, :, :Sq], lse[:, :, :Sq]


def _bwd(res, do, *, causal, window, scale, bq, bk, q_offset, interpret):
    q, k, v, o, lse = res
    B, H, Sq, d = q.shape
    _, G, Sk, _ = k.shape
    rep = H // G
    delta = jnp.sum(do.astype(jnp.float32) * o.astype(jnp.float32),
                    axis=-1)                                  # (B,H,Sq)
    qp = _pad_to(q, 2, bq)
    dop = _pad_to(do, 2, bq)
    lsep = _pad_to(lse, 2, bq)
    dltp = _pad_to(delta, 2, bq)
    kp = _pad_to(k, 2, bk)
    vp = _pad_to(v, 2, bk)
    Sq_p, Sk_p = qp.shape[2], kp.shape[2]
    n_q, n_kv = Sq_p // bq, Sk_p // bk
    off = q_offset + (Sk - Sq if causal and Sq != Sk else 0)

    common = dict(scale=scale, causal=causal, window=window, block_q=bq,
                  block_k=bk, kv_len=Sk, q_offset=off)
    qspec = pl.BlockSpec((1, 1, bq, d), lambda b, h, i, j: (b, h, i, 0))
    kspec = pl.BlockSpec((1, 1, bk, d),
                         lambda b, h, i, j, rep=rep: (b, h // rep, j, 0))
    rowspec = pl.BlockSpec((1, 1, bq), lambda b, h, i, j: (b, h, i))

    dq = pl.pallas_call(
        functools.partial(_bwd_dq_kernel, n_kv=n_kv, **common),
        grid=(B, H, n_q, n_kv),
        in_specs=[qspec, kspec, kspec, qspec, rowspec, rowspec],
        out_specs=qspec,
        out_shape=jax.ShapeDtypeStruct((B, H, Sq_p, d), q.dtype),
        scratch_shapes=[pltpu.VMEM((bq, d), jnp.float32)],
        compiler_params=compat.CompilerParams(
            dimension_semantics=(
                "parallel", "parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(qp, kp, vp, dop, lsep, dltp)[:, :, :Sq]

    # dk/dv per QUERY head (grid kv-parallel, q sequential), then summed
    # over each GQA group's rep query heads
    kq = pl.BlockSpec((1, 1, bk, d),
                      lambda b, h, j, i, rep=rep: (b, h // rep, j, 0))
    qq = pl.BlockSpec((1, 1, bq, d), lambda b, h, j, i: (b, h, i, 0))
    rq = pl.BlockSpec((1, 1, bq), lambda b, h, j, i: (b, h, i))
    okv = pl.BlockSpec((1, 1, bk, d), lambda b, h, j, i: (b, h, j, 0))
    dk_h, dv_h = pl.pallas_call(
        functools.partial(_bwd_dkv_kernel, n_q=n_q, **common),
        grid=(B, H, n_kv, n_q),
        in_specs=[qq, kq, kq, qq, rq, rq],
        out_specs=(okv, okv),
        out_shape=(jax.ShapeDtypeStruct((B, H, Sk_p, d), k.dtype),
                   jax.ShapeDtypeStruct((B, H, Sk_p, d), v.dtype)),
        scratch_shapes=[pltpu.VMEM((bk, d), jnp.float32),
                        pltpu.VMEM((bk, d), jnp.float32)],
        compiler_params=compat.CompilerParams(
            dimension_semantics=(
                "parallel", "parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(qp, kp, vp, dop, lsep, dltp)
    dk = dk_h[:, :, :Sk].reshape(B, G, rep, Sk, d).sum(2).astype(k.dtype)
    dv = dv_h[:, :, :Sk].reshape(B, G, rep, Sk, d).sum(2).astype(v.dtype)
    return dq, dk, dv


def make_flash_attention_vjp(*, causal: bool = True, window: int = 0,
                             scale: float | None = None,
                             block_q: int = 128, block_k: int = 128,
                             q_offset: int = 0,
                             interpret: bool = False):
    """Differentiable flash attention: q (B,H,Sq,d), k/v (B,G,Skv,d).

    Forward saves only (q, k, v, o, logsumexp); both backward kernels
    recompute probabilities tile-by-tile in VMEM (flash backward)."""

    @jax.custom_vjp
    def fa(q, k, v):
        sc = (q.shape[-1] ** -0.5) if scale is None else scale
        bq = min(block_q, max(q.shape[2], 8))
        bk = min(block_k, max(k.shape[2], 8))
        o, _ = _fwd_lse(q, k, v, causal=causal, window=window, scale=sc,
                        bq=bq, bk=bk, q_offset=q_offset,
                        interpret=interpret)
        return o

    def fwd(q, k, v):
        sc = (q.shape[-1] ** -0.5) if scale is None else scale
        bq = min(block_q, max(q.shape[2], 8))
        bk = min(block_k, max(k.shape[2], 8))
        o, lse = _fwd_lse(q, k, v, causal=causal, window=window,
                          scale=sc, bq=bq, bk=bk, q_offset=q_offset,
                          interpret=interpret)
        return o, (q, k, v, o, lse)

    def bwd(res, do):
        q = res[0]
        sc = (q.shape[-1] ** -0.5) if scale is None else scale
        bq = min(block_q, max(q.shape[2], 8))
        bk = min(block_k, max(res[1].shape[2], 8))
        return _bwd(res, do, causal=causal, window=window, scale=sc,
                    bq=bq, bk=bk, q_offset=q_offset, interpret=interpret)

    fa.defvjp(fwd, bwd)
    return fa
