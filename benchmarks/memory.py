"""Replica-state memory benchmark: the mixed-precision policy's byte
and wall-clock bill vs the all-f32 baseline.

DiLoCo's donated carry is dominated by the k-fold replica state — every
replica's params plus its AdamW m/v moments ride through the scanned
driver round after round. The precision policy (``optim/precision.py``)
shrinks exactly that tier:

  f32        param_dtype=float32, master_dtype=float32 — the baseline,
             bit-identical to the historical driver (gated below).
  bf16       param_dtype=bfloat16, master_dtype=float32 — THE mixed
             policy: bf16 working params + bf16 moments + an f32
             per-replica master inside the AdamW state. The
             params+moments carry halves (12 B -> 6 B per param per
             replica); the master adds 4 B back but keeps the update
             and the outer deltas at full precision.
  bf16_pure  param_dtype=master_dtype=bfloat16 — no master at all;
             smallest carry, lowest-precision outer gradients
             (informational, not gated).

Measured per policy:

  state_bytes.*            actual storage bytes of every state tier,
                           read off the initialized DiLoCoState leaves
                           (params / moments / master / global / outer);
  replica_params_moments_bytes   the gated tier: k×(params + m + v);
  compiled_memory          XLA's compiled-memory analysis of the
                           scanned run (argument/output/temp/donated
                           alias bytes) via launch/hlo_analysis.py —
                           best-effort, {} where the backend doesn't
                           report it;
  round_latency_ms         measured wall-clock per round (min over
                           repeats, donated carry, fresh state each
                           call);
  final_val_loss           end-of-run validation loss of the *global*
                           (always-f32) params;
  outer_sync_bytes         informational: simulated wire bytes of one
                           full-model outer exchange per transport
                           dtype (the *measured* transported-bytes gate
                           lives in benchmarks/streaming.py).

Claims (the regression gates):

  replica_state_reduction_ge_1p8   bf16 policy shrinks the
                           params+moments donated carry >= 1.8x;
  f32_bit_identical        the f32 policy's final state equals a
                           default-config (policy-less) run bit for bit;
  loss_gap_small           |val(bf16) - val(f32)| <= --loss-gap.

Writes ``BENCH_memory.json`` at the repo root (see benchmarks/README.md
for the reading guide).

Run:  PYTHONPATH=src python -m benchmarks.memory [--rounds 4 ...]
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import numpy as np

from . import common as C
from repro.configs.base import DiLoCoConfig, TrainConfig
from repro.core import diloco
from repro.kernels.ops import transport_bytes
from repro.launch import hlo_analysis
from repro.optim import precision

ROOT = os.path.join(os.path.dirname(os.path.abspath(__file__)), "..")
OUT_PATH = os.path.join(ROOT, "BENCH_memory.json")

POLICIES = [
    ("f32", "float32", "float32"),
    ("bf16", "bfloat16", "float32"),
    ("bf16_pure", "bfloat16", "bfloat16"),
]


def state_bytes(state: diloco.DiLoCoState) -> dict:
    """Storage bytes of each tier of the carry, from the real leaves."""
    tb = precision.tree_bytes
    out = {
        "replica_params": tb(state.replica_params),
        "inner_m": tb(state.inner_state.m),
        "inner_v": tb(state.inner_state.v),
        "inner_master": tb(state.inner_state.master),
        "global_params": tb(state.global_params),
        "outer_buffers": tb(state.outer_state.buf)
        + tb(state.outer_state.buf2),
    }
    out["replica_params_moments"] = (out["replica_params"]
                                     + out["inner_m"] + out["inner_v"])
    out["total"] = sum(v for k, v in out.items()
                       if k not in ("replica_params_moments",))
    return out


def bench_policy(loss_fn, sampler, params, name, dcfg, tcfg, *, rounds,
                 batch, seq, val, seed, repeats):
    run = diloco.make_run(loss_fn, sampler.sample_all_shards, dcfg,
                          tcfg, rounds_per_call=rounds,
                          total_steps=rounds * dcfg.H, batch_size=batch,
                          seq_len=seq, eval_tokens=val, eval_every=1,
                          donate=True)
    key = jax.random.PRNGKey(seed + 2)

    state0 = diloco.init_state(params, dcfg)
    sb = state_bytes(state0)
    # AOT-compile once: the same executable serves the memory analysis
    # AND the timed calls (compiling again through the jit cache would
    # double the dominant cost of the benchmark)
    try:
        compiled = run.lower(state0, key).compile()
        mem = hlo_analysis.memory_items(compiled)
        call = compiled
    except Exception:
        mem = {}
        call = run

    def one():
        state = diloco.init_state(params, dcfg)
        jax.block_until_ready(state)
        t0 = time.perf_counter()
        state, ms = call(state, key)
        jax.block_until_ready((state, ms))
        return time.perf_counter() - t0, state, ms

    one()                                           # warmup
    results = [one() for _ in range(repeats)]
    t = min(r[0] for r in results)
    _, state, ms = results[0]
    backend = jax.default_backend()
    # CPU executes bf16 arithmetic through f32 emulation (often with
    # extra convert traffic), so low-precision latency rows measured
    # there describe the emulator, not the policy — mark them
    # informational so nothing downstream gates on them
    emulated = (backend == "cpu" and (dcfg.param_dtype != "float32"
                                      or dcfg.master_dtype != "float32"))
    return {
        "name": name,
        "config": {"param_dtype": dcfg.param_dtype,
                   "master_dtype": dcfg.master_dtype},
        "state_bytes": sb,
        "compiled_memory": mem,
        "backend": backend,
        "latency_informational": emulated,
        "total_s": t,
        "round_latency_ms": 1e3 * t / rounds,
        "final_val_loss": float(np.asarray(ms["val_loss"])[-1]),
    }, state


def run(scale: int = 1, *, k=4, H=6, rounds=6, batch=2, seq=32,
        eval_batch=16, repeats=3, seed=0, loss_gap=0.25, out=OUT_PATH):
    rounds = rounds * scale
    arch, loss_fn, sampler = C.make_setup(k=k, seed=seed)
    total = rounds * H
    params, _ = C.pretrain(arch, loss_fn, sampler, 0, batch=batch,
                           seq=seq, lr=3e-3, warmup=10, total=total,
                           seed=seed)
    val = sampler.sample_validation(jax.random.PRNGKey(10_000),
                                    eval_batch, seq)
    n_params = int(sum(l.size for l in jax.tree.leaves(params)))
    print(f"k={k} H={H} rounds={rounds} batch={batch} seq={seq} "
          f"params={n_params} backend={jax.default_backend()}")

    runs, states = {}, {}
    for name, pd, md in POLICIES:
        dcfg = DiLoCoConfig(k=k, H=H, param_dtype=pd, master_dtype=md)
        tcfg = TrainConfig(inner_lr=3e-3, warmup_steps=10,
                           total_steps=total, batch_size=batch,
                           seq_len=seq, param_dtype=pd, master_dtype=md)
        r, st = bench_policy(loss_fn, sampler, params, name, dcfg, tcfg,
                             rounds=rounds, batch=batch, seq=seq,
                             val=val, seed=seed, repeats=repeats)
        runs[name] = r
        states[name] = st
        sb = r["state_bytes"]
        print(f"{name:10s} {r['round_latency_ms']:8.2f} ms/round  "
              f"val={r['final_val_loss']:.4f}  "
              f"p+m+v={sb['replica_params_moments']} B  "
              f"total={sb['total']} B")

    # --- gate 1: f32 policy == default (policy-less) config, bit for bit
    dcfg_d = DiLoCoConfig(k=k, H=H)
    tcfg_d = TrainConfig(inner_lr=3e-3, warmup_steps=10,
                         total_steps=total, batch_size=batch, seq_len=seq)
    run_d = diloco.make_run(loss_fn, sampler.sample_all_shards, dcfg_d,
                            tcfg_d, rounds_per_call=rounds,
                            total_steps=total, batch_size=batch,
                            seq_len=seq, eval_tokens=val, eval_every=1,
                            donate=True)
    st_d, _ = run_d(diloco.init_state(params, dcfg_d),
                    jax.random.PRNGKey(seed + 2))
    bit_identical = all(
        np.array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree.leaves(states["f32"]),
                        jax.tree.leaves(st_d)))

    # --- gate 2: >=1.8x params+moments reduction for the bf16 policy
    base = runs["f32"]["state_bytes"]["replica_params_moments"]
    reductions = {n: base / r["state_bytes"]["replica_params_moments"]
                  for n, r in runs.items() if n != "f32"}

    # --- gate 3: matched loss
    gap = abs(runs["bf16"]["final_val_loss"]
              - runs["f32"]["final_val_loss"])

    # informational: wire bytes of one full-model outer exchange per
    # transport dtype (the measured per-run gate on transported bytes
    # lives in benchmarks/streaming.py)
    sync_bytes = {dt: transport_bytes(n_params, dt)
                  for dt in ("float32", "bfloat16", "int4")}

    lat_ok = (runs["bf16"]["round_latency_ms"]
              <= 1.5 * runs["f32"]["round_latency_ms"])

    report = {
        "config": {"k": k, "H": H, "rounds": rounds, "batch": batch,
                   "seq": seq, "backend": jax.default_backend(),
                   "model_params": n_params},
        "runs": runs,
        "replica_state_reduction": reductions,
        "val_loss_gap_bf16_vs_f32": gap,
        "outer_sync_bytes": sync_bytes,
        "claims": {
            "replica_state_reduction_ge_1p8":
                bool(reductions["bf16"] >= 1.8),
            "f32_bit_identical": bool(bit_identical),
            "loss_gap_small": bool(gap <= loss_gap),
            "all_losses_finite": bool(all(
                np.isfinite(r["final_val_loss"])
                for r in runs.values())),
            # a real perf claim only where bf16 math is native; on
            # CPU the row is recorded but never gated (see
            # check_claims.informational)
            "bf16_latency_not_worse_1p5x": (
                {"value": bool(lat_ok), "informational": True,
                 "backend": jax.default_backend()}
                if runs["bf16"]["latency_informational"]
                else bool(lat_ok)),
        },
    }
    print(f"bit-identical f32: {bit_identical}   "
          f"p+m+v reductions: "
          + "  ".join(f"{n}={v:.2f}x" for n, v in reductions.items())
          + f"   loss gap: {gap:.4f}")

    with open(out, "w") as f:
        json.dump(report, f, indent=1)
    print("wrote", out)
    C.save("memory", report)
    return report


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--k", type=int, default=4)
    ap.add_argument("--H", type=int, default=6)
    ap.add_argument("--rounds", type=int, default=6)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--seq", type=int, default=32)
    ap.add_argument("--eval-batch", type=int, default=16)
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--loss-gap", type=float, default=0.25,
                    help="max |val(bf16) - val(f32)| for the "
                         "loss_gap_small claim")
    ap.add_argument("--out", default=OUT_PATH)
    a = ap.parse_args(argv)
    return run(1, k=a.k, H=a.H, rounds=a.rounds, batch=a.batch,
               seq=a.seq, eval_batch=a.eval_batch, repeats=a.repeats,
               seed=a.seed, loss_gap=a.loss_gap, out=a.out)


if __name__ == "__main__":
    main()
