"""Anomaly guard, both tiers: the in-graph NaN/Inf rejection and
norm-outlier clip in ``diloco.outer_step`` (``dcfg.guard_outer`` /
``guard_clip``), and the host-side ``resilience.AnomalyGuard`` rolling
statistics + rollback bookkeeping the launcher escalates through.

The load-bearing claims: a guarded CLEAN round is bit-identical to an
unguarded one (the guard must be free when nothing is wrong), and a
rejected replica is numerically identical to a zero-weight replica
(the guard composes with the Fig 8 drop semantics it reuses)."""
from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import DiLoCoConfig, TrainConfig
from repro.core import diloco
from repro.resilience import AnomalyGuard, GuardConfig


def quad_loss(p, batch):
    t = batch["tokens"].astype(jnp.float32).mean() / 7.0
    return (jnp.sum((p["w"] - t) ** 2)
            + 0.1 * jnp.sum(jnp.square(p["b"]))), {}


def tiny_params():
    return {"w": jnp.arange(8.0) / 8.0, "b": jnp.ones((3,))}


def sample_all(k):
    def fn(key, B, S):
        return jax.random.randint(key, (k, B, S), 0, 7, jnp.int32)
    return fn


def _assert_trees_equal(a, b):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        assert x.dtype == y.dtype
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def make_cfgs(k=4, **dkw):
    dcfg = DiLoCoConfig(k=k, H=2, outer_lr=0.3, **dkw)
    tcfg = TrainConfig(inner_lr=0.05, warmup_steps=2, total_steps=64,
                       batch_size=2, seq_len=4)
    return dcfg, tcfg


def drifted_state(dcfg, tcfg, rounds=2):
    """A state whose replicas have genuinely drifted from the global
    copy (so outer deltas are nonzero and the guard has work to judge)."""
    rnd = diloco.make_round(quad_loss, sample_all(dcfg.k), dcfg, tcfg,
                            total_steps=64)
    state = diloco.init_state(tiny_params(), dcfg)
    key = jax.random.PRNGKey(0)
    for t in range(rounds):
        state, _ = rnd(state, jax.random.fold_in(key, t))
    # desynchronize the replicas from the global so deltas are nonzero
    noise = jax.random.normal(jax.random.PRNGKey(5), (dcfg.k,)) * 0.01
    return state._replace(replica_params=jax.tree.map(
        lambda r: r + noise.reshape((dcfg.k,) + (1,) * (r.ndim - 1))
        .astype(r.dtype), state.replica_params))


# ---------------------------------------------------------------------------
# in-graph guard: outer_step under dcfg.guard_outer
# ---------------------------------------------------------------------------

def outer(state, dcfg, **kw):
    return jax.jit(lambda s: diloco.outer_step(s, dcfg, **kw))(state)


def test_guard_is_bit_identical_on_clean_rounds():
    dcfg, tcfg = make_cfgs()
    state = drifted_state(dcfg, tcfg)
    guarded = dataclasses.replace(dcfg, guard_outer=True)
    s0, m0 = outer(state, dcfg)
    s1, m1 = outer(state, guarded)
    _assert_trees_equal(s0, s1)
    assert float(m1["guard_rejected"]) == 0.0
    assert float(m0["outer_gnorm"]) == float(m1["outer_gnorm"])


def test_guard_with_clip_is_bit_identical_when_norms_agree():
    # replicas perturbed by comparable noise: no norm exceeds
    # guard_clip x median, so the scale is exactly 1.0 everywhere and
    # the multiply is an identity — clip enabled must cost nothing
    dcfg, tcfg = make_cfgs()
    state = drifted_state(dcfg, tcfg)
    clipped = dataclasses.replace(dcfg, guard_outer=True,
                                  guard_clip=100.0)
    s0, _ = outer(state, dcfg)
    s1, m1 = outer(state, clipped)
    _assert_trees_equal(s0, s1)
    assert float(m1["guard_clipped"]) == 0.0


def test_rejected_replica_equals_zero_weight_replica():
    """Bombing replica 0 with NaN under the guard must produce the
    same GLOBAL update as dropping replica 0's communication — the
    rejection literally is a zeroed weight. Re-dispatch differs by
    design: the dropped replica keeps its own params (Fig 8), the
    bombed one adopts the new global (its local state is poison)."""
    dcfg, tcfg = make_cfgs()
    state = drifted_state(dcfg, tcfg)
    k = dcfg.k
    guarded = dataclasses.replace(dcfg, guard_outer=True)
    bomb = jnp.zeros((k,)).at[0].set(1.0)
    drop = jnp.ones((k,)).at[0].set(0.0)

    s_bomb, m_bomb = outer(state, guarded, bomb_mask=bomb)
    s_drop, m_drop = outer(state, dcfg, drop_mask=drop)

    assert float(m_bomb["guard_rejected"]) == 1.0
    _assert_trees_equal(s_bomb.global_params, s_drop.global_params)
    _assert_trees_equal(s_bomb.outer_state, s_drop.outer_state)
    assert float(m_bomb["outer_gnorm"]) == float(m_drop["outer_gnorm"])
    # bombed replica re-dispatches from the new global...
    for g, r in zip(jax.tree.leaves(s_bomb.global_params),
                    jax.tree.leaves(s_bomb.replica_params)):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(r[0]))
    # ...while the dropped replica kept its own pre-round params
    kept = jax.tree.leaves(state.replica_params)[0][0]
    got = jax.tree.leaves(s_drop.replica_params)[0][0]
    np.testing.assert_array_equal(np.asarray(kept), np.asarray(got))


def test_unguarded_bomb_poisons_everything():
    # the negative control: without the guard the NaN reaches the
    # reduce and the global copy is gone
    dcfg, tcfg = make_cfgs()
    state = drifted_state(dcfg, tcfg)
    bomb = jnp.zeros((dcfg.k,)).at[1].set(1.0)
    s, _ = outer(state, dcfg, bomb_mask=bomb)
    assert not np.isfinite(
        np.asarray(jax.tree.leaves(s.global_params)[0])).all()


def test_all_replicas_bombed_keeps_global_finite():
    # denom floors at 1e-9; an all-rejected round must degenerate to
    # (approximately) no update, never to NaN
    dcfg, tcfg = make_cfgs()
    state = drifted_state(dcfg, tcfg)
    guarded = dataclasses.replace(dcfg, guard_outer=True)
    s, m = outer(state, guarded, bomb_mask=jnp.ones((dcfg.k,)))
    assert float(m["guard_rejected"]) == dcfg.k
    for leaf in jax.tree.leaves(s.global_params):
        assert np.isfinite(np.asarray(leaf)).all()


def test_guard_clip_tames_norm_outlier():
    dcfg, tcfg = make_cfgs()
    state = drifted_state(dcfg, tcfg)
    # blow up replica 2's delta by a factor the clip must catch
    boost = jnp.ones((dcfg.k,)).at[2].set(1000.0)
    state = state._replace(replica_params=jax.tree.map(
        lambda r, g: g[None] + (r - g[None]) * boost.reshape(
            (dcfg.k,) + (1,) * (r.ndim - 1)).astype(r.dtype),
        state.replica_params, state.global_params))
    clipped = dataclasses.replace(dcfg, guard_outer=True, guard_clip=4.0)
    s_clip, m_clip = outer(state, clipped)
    s_raw, m_raw = outer(state, dataclasses.replace(dcfg,
                                                    guard_outer=True))
    assert float(m_clip["guard_clipped"]) == 1.0
    assert float(m_clip["guard_rejected"]) == 0.0
    # the outlier dominated the unclipped average; clipping shrinks it
    assert float(m_clip["outer_gnorm"]) < float(m_raw["outer_gnorm"])
    for leaf in jax.tree.leaves(s_clip.global_params):
        assert np.isfinite(np.asarray(leaf)).all()


def test_scanned_run_with_nan_bombs_and_guard_stays_finite():
    """End-to-end through make_run: a mid-run NaN bomb row with the
    guard on yields finite losses every round and a finite state; the
    bombed round reports the rejection in its stacked metrics."""
    k, R = 4, 4
    dcfg, tcfg = make_cfgs(k, guard_outer=True)
    bombs = np.zeros((R, k), np.float32)
    bombs[2, 1] = 1.0
    val = jax.random.randint(jax.random.PRNGKey(9), (4, 4), 0, 7,
                             jnp.int32)
    run = diloco.make_run(quad_loss, sample_all(k), dcfg, tcfg,
                          rounds_per_call=R, total_steps=64,
                          batch_size=2, seq_len=4, eval_tokens=val,
                          nan_bombs=bombs, donate=False)
    state = diloco.init_state(tiny_params(), dcfg)
    state, ms = run(state, jax.random.PRNGKey(0), None, None, None)
    rej = np.asarray(ms["guard_rejected"])
    assert rej.shape == (R,)
    np.testing.assert_array_equal(rej, [0, 0, 1, 0])
    assert np.isfinite(np.asarray(ms["val_loss"])[-1])
    for leaf in jax.tree.leaves(state.global_params):
        assert np.isfinite(np.asarray(leaf)).all()


def test_nan_bombs_rejected_off_classic_simulated_transport():
    k = 4
    bombs = np.zeros((2, k), np.float32)
    dcfg, tcfg = make_cfgs(k, streaming_fragments=2)
    with pytest.raises(ValueError, match="nan_bombs"):
        diloco.make_run(quad_loss, sample_all(k), dcfg, tcfg,
                        rounds_per_call=2, total_steps=64,
                        batch_size=2, seq_len=4, nan_bombs=bombs)


# ---------------------------------------------------------------------------
# host-side guard: rolling stats, verdicts, escalation bookkeeping
# ---------------------------------------------------------------------------

class StubRecorder:
    def __init__(self):
        self.events = []

    def guard_event(self, *, action, round, **fields):
        self.events.append({"action": action, "round": round, **fields})


def test_guard_config_validation():
    with pytest.raises(ValueError, match="window"):
        GuardConfig(window=0)
    with pytest.raises(ValueError, match="spike"):
        GuardConfig(spike=0.0)
    with pytest.raises(ValueError, match="min_history"):
        GuardConfig(min_history=0)
    with pytest.raises(ValueError, match="max_rollbacks"):
        GuardConfig(max_rollbacks=-1)


def test_non_finite_loss_trips_immediately():
    g = AnomalyGuard(GuardConfig())
    v = g.observe(0, float("nan"))
    assert v == {**v, "ok": False, "reason": "non_finite"}
    assert g.observe(1, float("inf"))["reason"] == "non_finite"
    # the window never saw the anomalies
    assert math.isnan(g.stats()[0])


def test_spike_needs_history_and_spares_the_baseline():
    cfg = GuardConfig(window=8, spike=4.0, min_history=4)
    g = AnomalyGuard(cfg)
    # too little history: even a huge loss passes (cold start)
    assert g.observe(0, 100.0)["ok"]
    for r in range(1, 4):
        assert g.observe(r, 100.0 + 0.1 * r)["ok"]
    mean, std = g.stats()
    v = g.observe(4, mean + 4.0 * max(std, cfg.min_std) + 1.0)
    assert v == {**v, "ok": False, "reason": "spike"}
    # the spike was NOT folded into the window: stats unchanged,
    # so a normal follow-up round passes
    assert g.stats() == (mean, std)
    assert g.observe(5, mean)["ok"]


def test_flat_window_cannot_hair_trigger():
    # identical losses give std == 0; min_std floors the band so a
    # microscopic wobble is not an anomaly
    g = AnomalyGuard(GuardConfig(min_history=2, min_std=1e-3))
    for r in range(4):
        g.observe(r, 2.0)
    assert g.observe(4, 2.0 + 1e-4)["ok"]


def test_observe_chunk_returns_only_bad_verdicts():
    g = AnomalyGuard(GuardConfig(min_history=2))
    bad = g.observe_chunk(0, [3.0, 3.1, float("nan"), 3.2])
    assert [v["round"] for v in bad] == [2]
    assert bad[0]["reason"] == "non_finite"
    assert [v["round"] for v in g.verdicts] == [0, 1, 2, 3]


def test_rollback_budget_and_recorder_events():
    rec = StubRecorder()
    g = AnomalyGuard(GuardConfig(max_rollbacks=2), recorder=rec)
    g.observe(5, float("nan"))
    assert g.can_rollback()
    g.rolled_back(to_round=4, skip_round=5)
    g.rolled_back(to_round=4, skip_round=5)
    assert not g.can_rollback()
    assert g.rollbacks_used == 2 and g.skipped_rounds == {5}
    actions = [e["action"] for e in rec.events]
    assert actions == ["anomaly", "rollback", "rollback"]
    assert rec.events[0]["reason"] == "non_finite"
    assert rec.events[1] == {**rec.events[1], "round": 5,
                             "restored_to": 4, "rollbacks_used": 1}
