"""CheckpointManager durability contracts: atomic writes that survive
a mid-write interrupt, per-entry sha256 manifests that catch truncation
and bit rot, retention, and the ``latest_good`` resume picker falling
back past corrupt snapshots. The state codec's wrap/unwrap envelope and
hash gates ride along (they are what the manager snapshots)."""
from __future__ import annotations

import glob
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import checkpoint as ckpt
from repro.resilience import (CheckpointManager, harness, leaf_hashes,
                              state_codec, tree_sha256, unwrap, wrap)


def sample_tree(step: int) -> dict:
    """A tree with the interesting leaf kinds: f32, int32, bf16 (the
    npz bit-view path), nested dicts — varying with ``step`` so
    distinct snapshots have distinct bytes."""
    return {
        "w": jnp.arange(8.0) + step,
        "t": jnp.asarray(step, jnp.int32),
        "half": (jnp.ones((3,), jnp.bfloat16) * (1 + step)),
        "nest": {"b": jnp.zeros((2, 2)) + step},
    }


def _assert_trees_equal(a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        assert x.dtype == y.dtype
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ---------------------------------------------------------------------------
# save / verify / resume picker
# ---------------------------------------------------------------------------

def test_save_verify_latest_good(tmp_path):
    mgr = CheckpointManager(str(tmp_path), retain=5)
    assert mgr.steps() == [] and mgr.latest_good() is None
    for step in (2, 4, 6):
        path = mgr.save(step, sample_tree(step), metadata={"round": step})
        assert os.path.exists(path)
        assert os.path.exists(path + ".manifest.json")
    assert mgr.steps() == [2, 4, 6]
    assert all(mgr.verify(s) for s in (2, 4, 6))
    assert not mgr.verify(3)          # never written
    assert mgr.latest_good() == 6
    back = mgr.load(6, sample_tree(0))
    _assert_trees_equal(back, sample_tree(6))
    assert ckpt.load_metadata(mgr.path_of(6))["round"] == 6
    # structure-free view agrees leaf-for-leaf
    tree = mgr.load_tree(6)
    _assert_trees_equal(ckpt.reshape_like(tree, sample_tree(0)),
                        sample_tree(6))


def test_manifest_catches_truncation_and_bitflip(tmp_path):
    for mode in ("truncate", "bitflip"):
        d = str(tmp_path / mode)
        mgr = CheckpointManager(d)
        mgr.save(1, sample_tree(1))
        mgr.save(2, sample_tree(2))
        assert mgr.latest_good() == 2
        hit = harness.corrupt_latest(d, mode=mode)
        assert hit == mgr.path_of(2)
        assert not mgr.verify(2)
        # the resume picker falls back past the damaged snapshot
        assert mgr.latest_good() == 1
        _assert_trees_equal(mgr.load(1, sample_tree(0)), sample_tree(1))


def test_missing_manifest_means_unverified(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(3, sample_tree(3))
    os.unlink(mgr.path_of(3) + ".manifest.json")
    assert not mgr.verify(3)
    assert mgr.latest_good() is None
    # an unreadable manifest is as bad as a missing one
    mgr.save(5, sample_tree(5))
    with open(mgr.path_of(5) + ".manifest.json", "w") as f:
        f.write("{not json")
    assert not mgr.verify(5)


# ---------------------------------------------------------------------------
# atomicity: a crash mid-write must not damage the previous snapshot
# ---------------------------------------------------------------------------

def test_interrupted_save_leaves_old_snapshot_intact(tmp_path, monkeypatch):
    """Regression for the atomic-write fix: simulate the process dying
    midway through writing snapshot N+1 (partial bytes hit the temp
    file, then the 'crash'). Snapshot N must still verify and restore
    bit-identically, and no half-written file may occupy N+1's slot."""
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(10, sample_tree(10))
    golden = tree_sha256(ckpt.restore_tree(mgr.path_of(10)))

    real_savez = np.savez

    def dying_savez(f, **flat):
        f.write(b"PK\x03\x04partial")       # looks like a zip, is not
        f.flush()
        raise RuntimeError("simulated crash mid-write")

    monkeypatch.setattr(np, "savez", dying_savez)
    with pytest.raises(RuntimeError, match="mid-write"):
        mgr.save(20, sample_tree(20))
    monkeypatch.setattr(np, "savez", real_savez)

    # the interrupted step never made it into the directory, no temp
    # debris survives, and the old snapshot is byte-for-byte intact
    assert mgr.steps() == [10]
    assert glob.glob(str(tmp_path / "*.tmp")) == []
    assert mgr.verify(10) and mgr.latest_good() == 10
    assert tree_sha256(ckpt.restore_tree(mgr.path_of(10))) == golden
    # and the manager still works after the failed attempt
    mgr.save(20, sample_tree(20))
    assert mgr.latest_good() == 20


# ---------------------------------------------------------------------------
# retention
# ---------------------------------------------------------------------------

def test_retention_keeps_newest_and_drops_sidecars(tmp_path):
    mgr = CheckpointManager(str(tmp_path), retain=2)
    for step in range(1, 5):
        mgr.save(step, sample_tree(step), metadata={"round": step})
    assert mgr.steps() == [3, 4]
    leftovers = sorted(os.listdir(str(tmp_path)))
    for step in (1, 2):  # npz + manifest + meta all gone
        base = os.path.basename(mgr.path_of(step))
        assert not any(name.startswith(base) for name in leftovers)
    for step in (3, 4):
        assert mgr.verify(step)


def test_retain_must_be_positive(tmp_path):
    with pytest.raises(ValueError, match="retain"):
        CheckpointManager(str(tmp_path), retain=0)


# ---------------------------------------------------------------------------
# state codec: wrap/unwrap envelope + hash gates
# ---------------------------------------------------------------------------

def test_wrap_unwrap_roundtrip_through_manager(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    state = sample_tree(7)
    key = jax.random.PRNGKey(13)
    env = wrap(state, key, rounds_done=42)
    mgr.save(42, env)
    back_state, back_key, rounds = unwrap(mgr.load(42, env))
    assert rounds == 42
    _assert_trees_equal(back_state, state)
    np.testing.assert_array_equal(np.asarray(back_key), np.asarray(key))
    # the cursor rides as int32 so jax's x64-off restore cannot warn
    assert np.asarray(env["cursor"]["rounds_done"]).dtype == np.int32


def test_unwrap_rejects_unknown_format():
    env = wrap({"w": jnp.ones(2)}, jax.random.PRNGKey(0), 1)
    env["cursor"]["format"] = np.int32(99)
    with pytest.raises(ValueError, match="format"):
        unwrap(env)


def test_tree_sha256_detects_any_leaf_change(tmp_path):
    a = sample_tree(1)
    assert tree_sha256(a) == tree_sha256(sample_tree(1))
    b = sample_tree(1)
    b["half"] = b["half"].at[1].set(jnp.bfloat16(9.0))
    assert tree_sha256(a) != tree_sha256(b)
    # a dtype change with identical bytes is still a different tree
    c = dict(a)
    c["t"] = jnp.asarray(np.asarray(a["t"]).view(np.uint32))
    assert tree_sha256(a) != tree_sha256(c)
    # per-leaf view pinpoints exactly the changed leaf
    ha, hb = leaf_hashes(a), leaf_hashes(b)
    assert set(ha) == set(hb)
    diff = [k for k in ha if ha[k] != hb[k]]
    assert diff == ["['half']"]


def test_manifest_hashes_on_disk_representation(tmp_path):
    """The manifest must hash what is ON DISK (bf16 as its uint16 bit
    view) so verify never depends on ml_dtypes being importable for
    the raw npz — cross-checked by hashing the file twice."""
    mgr = CheckpointManager(str(tmp_path))
    path = mgr.save(1, sample_tree(1))
    with open(path + ".manifest.json") as f:
        manifest = json.load(f)
    from repro.resilience.manager import _npz_entry_hashes
    assert manifest["entries"] == _npz_entry_hashes(path)
    assert manifest["step"] == 1
    # every npz entry is covered — nothing silently unhashed
    with np.load(path) as data:
        assert sorted(manifest["entries"]) == sorted(data.files)


def test_state_codec_format_pinned():
    # bumping the envelope format is a compatibility event; this pin
    # forces the bump to be intentional
    assert state_codec._FORMAT == 1
