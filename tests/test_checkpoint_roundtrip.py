"""Checkpoint round-trips for every training-state shape the drivers
can carry: DiLoCoState (classic), StreamState (streaming, with and
without error-feedback residuals), AdamWState under a mixed precision
policy (bf16 moments + f32 masters), and the dtype/metadata contracts
of the npz container. The async engine's full-state round-trip (and
the preempted-and-restored bit-identity) lives in
tests/test_async_engine.py; the gossip slice in tests/test_gossip.py.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import checkpoint as ckpt
from repro.configs.base import DiLoCoConfig, TrainConfig
from repro.core import diloco, streaming
from repro.optim import adamw, precision


def quad_loss(p, batch):
    t = batch["tokens"].astype(jnp.float32).mean() / 7.0
    return (jnp.sum((p["w"] - t) ** 2)
            + 0.1 * jnp.sum(jnp.square(p["b"]))), {}


def tiny_params():
    return {"w": jnp.arange(8.0) / 8.0, "b": jnp.ones((3,))}


def sample_all(k):
    def fn(key, B, S):
        return jax.random.randint(key, (k, B, S), 0, 7, jnp.int32)
    return fn


def _assert_trees_equal(a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        assert x.dtype == y.dtype
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def _advanced_state(dcfg, tcfg, init_fn, rounds=2):
    rnd = diloco.make_round(quad_loss, sample_all(dcfg.k), dcfg, tcfg,
                            total_steps=64)
    state = init_fn(tiny_params(), dcfg)
    key = jax.random.PRNGKey(0)
    for t in range(rounds):
        state, _ = rnd(state, jax.random.fold_in(key, t))
    return state


def test_diloco_state_roundtrip(tmp_path):
    dcfg = DiLoCoConfig(k=2, H=2, outer_lr=0.3)
    tcfg = TrainConfig(inner_lr=0.05, warmup_steps=2, total_steps=64,
                       batch_size=2, seq_len=4)
    state = _advanced_state(dcfg, tcfg, diloco.init_state)
    path = str(tmp_path / "diloco.npz")
    ckpt.save(path, state, metadata={"phase": "diloco", "round": 2})
    back = ckpt.restore(path, state)
    assert isinstance(back, diloco.DiLoCoState)
    _assert_trees_equal(state, back)
    meta = ckpt.load_metadata(path)
    assert meta["phase"] == "diloco" and meta["round"] == 2
    # and training continues from the restored state exactly as from
    # the original: one more round on each must agree bitwise
    rnd = diloco.make_round(quad_loss, sample_all(2), dcfg, tcfg,
                            total_steps=64)
    k2 = jax.random.PRNGKey(7)
    s1, m1 = rnd(state, k2)
    s2, m2 = rnd(back, k2)
    _assert_trees_equal(s1, s2)
    assert float(m1["inner_loss"]) == float(m2["inner_loss"])


@pytest.mark.parametrize("ef", [False, True])
def test_stream_state_roundtrip(tmp_path, ef):
    dcfg = DiLoCoConfig(k=2, H=4, outer_lr=0.3, streaming_fragments=2,
                        stream_tau=1,
                        outer_grad_dtype="bfloat16" if ef else "float32",
                        error_feedback=ef)
    tcfg = TrainConfig(inner_lr=0.05, warmup_steps=2, total_steps=64,
                       batch_size=2, seq_len=4)
    state = _advanced_state(dcfg, tcfg, streaming.init_state)
    # a mid-run streaming state is the interesting one: armed latches
    # set, pending holds an in-flight fragment, residuals nonzero
    assert float(np.asarray(state.armed).sum()) > 0
    if ef:
        assert any(float(np.abs(np.asarray(r)).sum()) > 0
                   for r in jax.tree.leaves(state.residual))
    path = str(tmp_path / "stream.npz")
    ckpt.save(path, state)
    back = ckpt.restore(path, state)
    assert isinstance(back, streaming.StreamState)
    _assert_trees_equal(state, back)
    # structure-free view reshapes onto the nested NamedTuple too
    again = ckpt.reshape_like(ckpt.restore_tree(path), state)
    _assert_trees_equal(state, again)


def test_adamw_mixed_policy_roundtrip(tmp_path):
    pol = precision.make_policy("bfloat16", "float32")
    params = tiny_params()
    st = adamw.init(params, policy=pol)
    assert st.master is not None

    def scalar_loss(p):
        return quad_loss(p, {"tokens": jnp.zeros((2, 4),
                                                 jnp.int32)})[0]

    @jax.jit
    def step(w, s):
        g = jax.grad(scalar_loss)(adamw.master_params(w, s))
        return adamw.update(g, s, w, lr=0.05, policy=pol)

    # advance it so moments are nonzero and master/working drift apart
    work = precision.cast_tree(params, pol.param_dtype)
    for _ in range(3):
        work, st = step(work, st)
    path = str(tmp_path / "adamw.npz")
    ckpt.save(path, (work, st))
    w2, st2 = ckpt.restore(path, (work, st))
    assert jax.tree.leaves(w2)[0].dtype == jnp.bfloat16
    assert jax.tree.leaves(st2.master)[0].dtype == jnp.float32
    _assert_trees_equal((work, st), (w2, st2))
    # resumed step is bit-identical to the uninterrupted one
    _assert_trees_equal(step(work, st), step(w2, st2))


def test_packed_weights_roundtrip(tmp_path):
    # int4 packed serving checkpoint: manifest structure checks, the
    # streamed host-side restore, and the traceable in-graph rebuild
    # must agree bitwise with each other (same codec) and stay within
    # quantization error of the source
    key = jax.random.PRNGKey(0)
    params = {"a": jax.random.normal(key, (64, 33)),
              "nest": {"b": jax.random.normal(jax.random.fold_in(key, 1),
                                              (257,)),
                       "c": jnp.arange(6.0).reshape(2, 3)}}
    path = str(tmp_path / "w.packed.npz")
    man = ckpt.save_packed(path, params, n_fragments=3)
    assert man["format"] == ckpt.PACKED_FORMAT
    assert man["f32_bytes"] == 4 * sum(np.asarray(l).size
                                       for l in jax.tree.leaves(params))
    assert man["packed_bytes"] < man["f32_bytes"] / 5

    back = ckpt.restore_packed(path, params)
    packed = ckpt.load_packed(path)
    graph = jax.jit(lambda bufs: ckpt.unpack_params(
        bufs, manifest=packed["manifest"], example_tree=params))(
        {k: jnp.asarray(v) for k, v in packed["buffers"].items()})
    _assert_trees_equal(back, graph)
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(back)):
        # symmetric int4 with per-128 block scales: |err| <= scale step
        step = np.abs(np.asarray(a)).max() / 7.0
        assert np.abs(np.asarray(a) - np.asarray(b)).max() <= step + 1e-6

    # structure mismatches are rejected up front
    with pytest.raises(KeyError):
        ckpt.restore_packed(path, {"a": params["a"]})


def test_restore_rejects_shape_and_key_mismatch(tmp_path):
    state = {"w": jnp.ones((4,)), "b": jnp.zeros((2,))}
    path = str(tmp_path / "s.npz")
    ckpt.save(path, state)
    with pytest.raises(ValueError, match="shape"):
        ckpt.restore(path, {"w": jnp.ones((5,)), "b": jnp.zeros((2,))})
    with pytest.raises(KeyError):
        ckpt.restore(path, {"w": jnp.ones((4,)), "extra": jnp.ones(1)})
    with pytest.raises(KeyError):
        ckpt.reshape_like({"w": np.ones((4,))}, state)
