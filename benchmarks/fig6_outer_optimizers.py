"""Figure 6: comparison of outer optimizers.

SGD(lr=1) == FedAvg; Adam == FedOpt (eps raised to 0.1 as the paper
found necessary); Nesterov is the paper's pick. Expectation: Nesterov
best, plain SGD worst."""
from __future__ import annotations

from . import common as C

OPTS = [("sgd", dict(outer_lr=1.0)),
        ("sgdm", dict(outer_lr=0.3, outer_momentum=0.9)),
        ("nesterov", dict(outer_lr=0.7, outer_momentum=0.9)),
        ("adam", dict(outer_lr=0.3, adam_eps=0.1))]


def run(scale: int = 1):
    p = dict(C.DEFAULTS)
    rounds = 20 * scale
    arch, loss_fn, sampler = C.make_setup("non_iid", k=p["k"])
    params0, pre = C.pretrain(arch, loss_fn, sampler, p["pretrain"],
                              batch=p["batch"], seq=p["seq"],
                              lr=p["inner_lr"], warmup=p["warmup"],
                              total=p["pretrain"] + rounds * p["H"])
    rows = []
    for name, kw in OPTS:
        h, _ = C.run_diloco(arch, loss_fn, sampler, params0, k=p["k"],
                            H=p["H"], rounds=rounds, step0=pre,
                            outer_opt=name, batch=p["batch"],
                            seq=p["seq"],
                            eval_every=max(rounds // 10, 1), **kw)
        rows.append(dict(opt=name, ppl=C.final_ppl(h), curve=h))
    ppl = {r["opt"]: r["ppl"] for r in rows}
    payload = {"rows": rows,
               "claims": {"nesterov_best":
                          ppl["nesterov"] <= min(ppl.values()) * 1.01,
                          "nesterov_beats_sgd":
                          ppl["nesterov"] < ppl["sgd"]}}
    C.save("fig6_outer_optimizers", payload)
    return payload


if __name__ == "__main__":
    out = run()
    for r in out["rows"]:
        print(f"{r['opt']:10s} ppl={r['ppl']:.3f}")
    print(out["claims"])
