"""The paper's 400M Chinchilla-style transformer (Table 1): 12L,
hidden 1536, 12 heads, K/V size 128, vocab 32000."""
from .base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="diloco-400m", family="dense",
        n_layers=12, d_model=1536, n_heads=12, n_kv_heads=12,
        head_dim=128, d_ff=6144, vocab_size=32_000,
        pos_emb="rope", norm="rmsnorm", act="silu", mlp_gated=True,
    )


def smoke_config() -> ModelConfig:
    return config().replace(
        name="diloco-400m-smoke", n_layers=2, d_model=128, n_heads=4,
        n_kv_heads=4, head_dim=32, d_ff=256, vocab_size=256,
        attn_chunk=64)
